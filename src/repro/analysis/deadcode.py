"""Dead-code sweep: unreferenced public symbols and modules in src/repro.

Name-based and deliberately conservative: a top-level public function/
class counts as referenced if its bare name occurs ANYWHERE else in the
repo (attribute access, call, import, decorator — any mention); a module
counts as referenced only via a real import of its dotted path.  That
direction of error never flags live code spuriously; it can miss dead
code that shares a name with live code, which is fine for a gate.

Known-unreferenced scaffolding is not deleted silently: it lives in the
allowlist file (``deadcode_allow.txt``) where every entry must carry a
one-line justification — ROADMAP points at ``launch/elastic.py`` /
``launch/mesh.py`` as the tensor-parallel scale-out seam, so they stay.
Entries that become referenced again are reported as stale (prune the
allowlist, not a failure); entries without a justification are
violations.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set, Tuple

DEFAULT_ALLOWLIST = Path(__file__).with_name("deadcode_allow.txt")
SCAN_ROOTS = ("src", "tests", "benchmarks", "scripts", "examples")


def _py_files(root: Path):
    return (p for p in sorted(root.rglob("*.py"))
            if "__pycache__" not in p.parts)


def _module_dotted(path: Path, src_root: Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _definitions(src_pkg: Path, src_root: Path) -> Dict[str, List[str]]:
    """module dotted path -> its top-level public function/class names."""
    defs: Dict[str, List[str]] = {}
    for path in _py_files(src_pkg):
        tree = ast.parse(path.read_text(), filename=str(path))
        names = [n.name for n in tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef))
                 and not n.name.startswith("_")]
        defs[_module_dotted(path, src_root)] = names
    return defs


def _references(repo_root: Path) -> Tuple[Set[str], Set[str]]:
    """(mentioned names, imported dotted module paths) across the repo."""
    names: Set[str] = set()
    imports: Set[str] = set()
    for root in SCAN_ROOTS:
        base = repo_root / root
        if not base.is_dir():
            continue
        for path in _py_files(base):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in ast.walk(tree):
                if isinstance(node, ast.Name):
                    names.add(node.id)
                elif isinstance(node, ast.Attribute):
                    names.add(node.attr)
                elif isinstance(node, ast.Import):
                    for a in node.names:
                        imports.add(a.name)
                        names.add((a.asname or a.name).split(".")[0])
                elif isinstance(node, ast.ImportFrom):
                    mod = node.module or ""
                    imports.add(mod)
                    for a in node.names:
                        imports.add(f"{mod}.{a.name}" if mod else a.name)
                        names.add(a.asname or a.name)
                elif isinstance(node, ast.Constant) \
                        and isinstance(node.value, str):
                    if node.value.isidentifier():
                        # __all__ strings, getattr names, registry keys
                        names.add(node.value)
                    elif "." in node.value and all(
                            p.isidentifier()
                            for p in node.value.split(".")):
                        # dotted module paths loaded dynamically (the
                        # configs/__init__ importlib registry)
                        imports.add(node.value)
    return names, imports


def load_allowlist(path: Path = DEFAULT_ALLOWLIST,
                   ) -> Tuple[Dict[str, str], List[str]]:
    """entry -> justification, plus violations for unjustified entries."""
    allow: Dict[str, str] = {}
    violations: List[str] = []
    if not path.is_file():
        return allow, violations
    for i, raw in enumerate(path.read_text().splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        entry, _, why = line.partition(":")
        entry, why = entry.strip(), why.strip()
        if not why:
            violations.append(
                f"{path}:{i}: allowlist entry '{entry}' has no "
                "justification ('name: why it stays')")
        allow[entry] = why
    return allow, violations


def sweep(repo_root, allowlist_path: Path = DEFAULT_ALLOWLIST) -> dict:
    """Full sweep.  Returns ``violations`` (unreferenced and not
    allowlisted, or unjustified allowlist lines), ``allowlisted`` (dead
    but explained), and ``stale_allowlist`` (explained but alive)."""
    repo_root = Path(repo_root)
    src_root = repo_root / "src"
    defs = _definitions(src_root / "repro", src_root)
    names, imports = _references(repo_root)
    allow, violations = load_allowlist(allowlist_path)

    unreferenced: List[str] = []
    for mod, symbols in defs.items():
        parent, _, base = mod.rpartition(".")
        mod_used = mod in imports or (parent in imports and base in names) \
            or any(imp.startswith(mod + ".") for imp in imports)
        if not mod_used:
            unreferenced.append(mod)
            continue           # a dead module subsumes its symbols
        unreferenced.extend(f"{mod}.{s}" for s in symbols
                            if s not in names)

    flagged, allowlisted = [], []
    for item in unreferenced:
        bare = item.rpartition(".")[2]
        if item in allow or bare in allow:
            allowlisted.append(item)
        else:
            flagged.append(item)
    violations.extend(
        f"unreferenced public symbol/module: {it} — delete it or add a "
        f"justified line to {allowlist_path.name}" for it in flagged)
    dead = set(unreferenced)
    stale = [e for e in allow
             if e not in dead and not any(d.rpartition(".")[2] == e
                                          or d == e for d in dead)]
    return {"violations": violations, "allowlisted": allowlisted,
            "stale_allowlist": stale,
            "n_definitions": sum(len(v) for v in defs.values())}
