"""Engines + workloads the analyzer checks — smoke-sized, CPU-cheap.

One place (shared by scripts/analyze.py and tests/test_analysis.py)
builds the serving configurations the contracts run against, so the
analyzer and its regression tests cannot drift apart.  Three engines
cover the four dispatch shapes the ISSUE names:

  * ``quantized``      — packed weights + int8 contiguous cache:
                         ``prefill`` and scanned ``decode``.
  * ``spec_chunked``   — same, plus an n-gram draft (k=3) and
                         ``prefill_chunk=4``: the ``spec_verify`` and
                         ``fused_prefill_decode`` widths.
  * ``sharded``        — packed + int8 under a 1-device "model" mesh:
                         the shard_map'd decode the collective-count
                         contract walks (the psum structure is identical
                         at any shard count; a 1-device mesh traces it
                         on any host).
  * ``sharded_paged``  — the same mesh with ``cache_layout="paged"``:
                         the sharded PAGED decode dispatch (page pools
                         sharded on the KV-head axis, block table
                         replicated).  Traced so the collective /
                         baked-consts / dtype contracts cover the
                         paged+mesh composition, not just contiguous.

The retrace workloads drive real schedulers (mixed prompt lengths,
staggered admission, tail chunks, speculation) and read back
``dispatch_audit()`` — the one dynamic step in an otherwise static pass.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as tf
from repro.parallel.context import local_context
from repro.serve import pack_params
from repro.serve.config import DraftSpec, EngineSpec
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

ENGINE_KINDS = ("quantized", "spec_chunked", "sharded", "sharded_paged")
MAX_SEQ = 64
DECODE_CHUNK = 4
PREFILL_CHUNK = 4
DRAFT_K = 3
PROMPT_BUCKET = 16
PAGE_SIZE = 8


def _packed_setup():
    cfg = configs.get_config("olmo-1b").smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    arr = tf.build_policy(cfg).as_arrays()
    packed = pack_params(params, arr, cfg, cache_bits=8)
    return cfg, packed, arr


def build_engine(kind: str) -> ServeEngine:
    cfg, packed, arr = _packed_setup()
    base = dict(weights="packed", cache="quantized", cache_bits=8,
                decode_chunk=DECODE_CHUNK)
    if kind == "quantized":
        spec = EngineSpec(**base)
    elif kind == "spec_chunked":
        spec = EngineSpec(**base, prefill_chunk=PREFILL_CHUNK,
                          draft=DraftSpec(kind="ngram", k=DRAFT_K))
    elif kind == "sharded":
        mesh = jax.make_mesh((1,), ("model",))
        spec = EngineSpec(**base, mesh=mesh)
    elif kind == "sharded_paged":
        mesh = jax.make_mesh((1,), ("model",))
        spec = EngineSpec(**base, mesh=mesh, cache_layout="paged",
                          page_size=PAGE_SIZE)
    else:
        raise ValueError(f"unknown engine kind {kind!r}; "
                         f"one of {ENGINE_KINDS}")
    return ServeEngine(cfg=cfg, params=packed, policy_arrays=arr,
                       ctx=local_context(), max_seq=MAX_SEQ, spec=spec)


def _requests(n: int = 6) -> list:
    """Mixed prompt lengths and budgets: short and long prompts (bucket
    boundaries on both sides), token budgets that force tail chunks, and
    more requests than slots so admission staggers."""
    out = []
    for i in range(n):
        p_len = (3, 9, 17, 5, 21, 12)[i % 6]
        budget = (5, 7, 11, 4, 9, 6)[i % 6]
        out.append(Request(uid=f"r{i}",
                           prompt=[(7 * i + j) % 512 for j in range(p_len)],
                           max_new_tokens=budget))
    return out


def run_retrace_workloads() -> Dict[str, dict]:
    """Drive each scheduler-facing engine through a mixed workload and
    return workload name -> ``dispatch_audit()``."""
    audits = {}
    for kind in ("quantized", "spec_chunked"):
        eng = build_engine(kind)
        sched = ContinuousBatchingScheduler(eng, n_slots=3,
                                            prompt_bucket=PROMPT_BUCKET)
        for req in _requests():
            sched.submit(req)
        sched.run()
        # a second wave over the SAME engine: warm jit caches must be
        # reused, not re-traced (the audit would catch per-wave leaks)
        for req in _requests(3):
            sched.submit(Request(uid=req.uid + "b", prompt=req.prompt,
                                 max_new_tokens=req.max_new_tokens))
        sched.run()
        audits[kind] = sched.dispatch_audit()
    # solo generate on a fresh engine: chunk + exact tail geometry
    eng = build_engine("quantized")
    eng.generate(jnp.zeros((2, 8), jnp.int32), n_new=DECODE_CHUNK + 2)
    sizes, budget = eng.jit_cache_sizes(), eng.dispatch_budget(PROMPT_BUCKET)
    audits["generate_tail"] = {
        "sizes": sizes, "budget": budget,
        "over": {k: {"traces": v, "budget": budget[k]}
                 for k, v in sizes.items()
                 if k in budget and v > budget[k]}}
    return audits
