"""Fault-tolerant checkpointing: atomic commits, async writes, retention,
auto-resume.

Layout:  <dir>/step_<N>/
            meta.json            step, wall-time, mesh shape, data cursor,
                                 pytree structure (path list)
            <flat-key>.npy       one file per leaf (paths joined with '.')
         <dir>/step_<N>.tmp/     in-flight write (never resumed from)

Commit protocol: write to step_N.tmp, fsync, os.rename -> step_N (atomic on
POSIX).  Resume picks the largest committed step.  Async mode runs the
save on a background thread (the caller passes host-fetched numpy arrays —
jax.device_get happens on the training thread to keep a consistent cut).

Sharded arrays: each leaf is fetched via ``jax.device_get`` which gathers to
host; on real multi-host pods, per-host shard files + a shard index would
replace this single-file path (documented in README §runbook) — the
interface (save/restore/latest_step) is unchanged.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra_meta: Optional[dict] = None,
             block: bool = False) -> None:
        """Snapshot `tree` at `step`. Device arrays are fetched synchronously
        (consistent cut); file I/O happens on a background thread unless
        async_save=False or block=True."""
        leaves = [(k, np.asarray(jax.device_get(v))) for k, v in
                  _flatten(tree)]
        meta = {"step": int(step), "time": time.time(),
                "keys": [k for k, _ in leaves]}
        if extra_meta:
            meta.update(extra_meta)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, leaves, meta)

    def _write(self, step: int, leaves, meta) -> None:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in leaves:
            fname = key.replace("/", "_") + ".npy"
            # portable on-disk dtypes: bf16/f16 -> f32 (lossless upcast),
            # sub-byte ints -> int8; restore() casts back to the leaf dtype.
            if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16",
                                                           "float16"):
                arr = arr.astype(np.float32)
            elif str(arr.dtype) in ("int4", "uint4", "int2", "uint2"):
                arr = arr.astype(np.int8)
            np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._retain()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "meta.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}",
                               "meta.json")) as f:
            return json.load(f)

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of `like_tree` (shapes must match).
        `shardings`: optional matching pytree of NamedShardings — leaves are
        device_put with their target sharding (elastic re-shard on load)."""
        d = os.path.join(self.directory, f"step_{step}")
        flat_like = _flatten(like_tree)
        flat_shard = (_flatten(shardings) if shardings is not None
                      else [(k, None) for k, _ in flat_like])
        shard_map_ = dict(flat_shard)
        out = []
        for key, leaf in flat_like:
            arr = np.load(os.path.join(d, key.replace("/", "_") + ".npy"))
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = jax.numpy.asarray(arr).astype(leaf.dtype)
            sh = shard_map_.get(key)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like_tree)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like_tree, shardings)
